"""Span tracing with Chrome ``trace_event`` and JSONL export.

Spans are recorded *after the fact* — every call site in the stack already
knows both endpoints of the interval it measured (``arrival``/``dispatch_t``/
``complete_t`` stamps in ``serve.sched``, wall timers around kernels), so
there is no begin/end token API to keep balanced, just:

    trace.span("compute", cat="sched", track="requests", t0=a, t1=b, seq=7)
    trace.instant("retrace", cat="compile", track="compile", bucket=8)

Timestamps come from the injected clock domain (``FakeClock`` seconds in
simulations, ``time.monotonic`` live), so under a seeded simulation the
whole event log is deterministic.

Export formats:

* ``chrome()`` — a Chrome ``trace_event`` JSON object (Perfetto /
  chrome://tracing loadable): ``ph:"X"`` complete events with µs ``ts``/
  ``dur``, ``ph:"i"`` instants, plus ``ph:"M"`` metadata naming each track.
  Tracks map to ``pid=1`` and a ``tid`` assigned by sorted track name at
  export time, so the mapping never depends on recording order.
* ``jsonl()`` — one JSON object per event, in recording order.

Volatility: a simulation driven by a ``FakeClock`` is deterministic, but
kernel-profile *durations* are wall-clock measurements and some span args
(``wall_us``, ``gbps``, ``vs_roofline``…) derive from them.  Those fields
are enumerated here (``VOLATILE_ARGS`` / ``VOLATILE_CATS``) and stripped by
``strip_volatile=True`` exports, which is what the byte-identical trace
determinism tests compare.  docs/observability.md documents the contract.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Dict, List, Optional

__all__ = [
    "TraceEvent", "Trace", "VOLATILE_ARGS", "VOLATILE_CATS",
    "strip_volatile_events",
]

# Args whose values are wall-clock-derived even in virtual-time runs.
VOLATILE_ARGS = frozenset({
    "wall_us", "wall_ms", "wall_s", "gbps", "vs_roofline", "us_per_call",
})

# Event categories whose ts/dur are wall measurements rather than values in
# the injected clock domain (kernel profiling times real executions).
VOLATILE_CATS = frozenset({"kernel"})


@dataclasses.dataclass
class TraceEvent:
    """One event: ``ph`` is the Chrome phase ("X" complete span, "i"
    instant).  ``ts``/``dur`` are seconds in the trace's clock domain."""

    ph: str
    name: str
    cat: str
    track: str
    ts: float
    dur: float = 0.0
    args: Optional[Dict[str, Any]] = None

    def to_dict(self) -> dict:
        d = dict(ph=self.ph, name=self.name, cat=self.cat, track=self.track,
                 ts=self.ts)
        if self.ph == "X":
            d["dur"] = self.dur
        if self.args:
            d["args"] = self.args
        return d


def _strip_args(args: Optional[Dict[str, Any]]) -> Optional[Dict[str, Any]]:
    if not args:
        return args
    kept = {k: v for k, v in args.items() if k not in VOLATILE_ARGS}
    return kept or None


def strip_volatile_events(events: List[TraceEvent]) -> List[TraceEvent]:
    """Copy of ``events`` with the documented volatile content removed:
    volatile args dropped everywhere; ``ts``/``dur`` zeroed for events in
    ``VOLATILE_CATS``.  What remains must be byte-identical across seeded
    ``FakeClock`` runs."""
    out = []
    for e in events:
        wall = e.cat in VOLATILE_CATS
        out.append(TraceEvent(ph=e.ph, name=e.name, cat=e.cat, track=e.track,
                              ts=0.0 if wall else e.ts,
                              dur=0.0 if wall else e.dur,
                              args=_strip_args(e.args)))
    return out


class Trace:
    """An append-only event log bound to an injectable clock."""

    def __init__(self, clock=None):
        self.clock = clock
        self.events: List[TraceEvent] = []
        # event subscribers (the flight recorder's ring): called with each
        # TraceEvent as it is recorded.  Empty list = one falsy check.
        self.listeners: List = []

    def now(self) -> float:
        return self.clock.now() if self.clock is not None \
            else time.monotonic()

    def __len__(self) -> int:
        return len(self.events)

    # -- recording ----------------------------------------------------------

    def span(self, name: str, cat: str = "", track: str = "main",
             t0: Optional[float] = None, t1: Optional[float] = None,
             **args) -> TraceEvent:
        """Record a complete span [t0, t1] (defaults: both = now)."""
        if t1 is None:
            t1 = self.now()
        if t0 is None:
            t0 = t1
        e = TraceEvent(ph="X", name=name, cat=cat, track=track,
                       ts=float(t0), dur=max(float(t1) - float(t0), 0.0),
                       args=dict(args) if args else None)
        self.events.append(e)
        if self.listeners:
            for fn in self.listeners:
                fn(e)
        return e

    def instant(self, name: str, cat: str = "", track: str = "main",
                t: Optional[float] = None, **args) -> TraceEvent:
        e = TraceEvent(ph="i", name=name, cat=cat, track=track,
                       ts=float(t) if t is not None else self.now(),
                       args=dict(args) if args else None)
        self.events.append(e)
        if self.listeners:
            for fn in self.listeners:
                fn(e)
        return e

    # -- export -------------------------------------------------------------

    def _tids(self) -> Dict[str, int]:
        # sorted-by-name assignment: independent of recording order
        return {t: i + 1
                for i, t in enumerate(sorted({e.track for e in self.events}))}

    def chrome(self, strip_volatile: bool = False) -> dict:
        """Chrome ``trace_event`` JSON object (µs timestamps)."""
        events = strip_volatile_events(self.events) if strip_volatile \
            else self.events
        tids = self._tids()
        out: List[dict] = [
            {"ph": "M", "pid": 1, "tid": tid, "name": "thread_name",
             "args": {"name": track}}
            for track, tid in sorted(tids.items(), key=lambda kv: kv[1])
        ]
        for e in events:
            d: Dict[str, Any] = {
                "ph": e.ph, "name": e.name, "cat": e.cat or "default",
                "pid": 1, "tid": tids[e.track],
                "ts": round(e.ts * 1e6, 3),
            }
            if e.ph == "X":
                d["dur"] = round(e.dur * 1e6, 3)
            elif e.ph == "i":
                d["s"] = "t"                      # thread-scoped instant
            if e.args:
                d["args"] = e.args
            out.append(d)
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def jsonl(self, strip_volatile: bool = False) -> str:
        """One JSON object per line, recording order, seconds timestamps."""
        events = strip_volatile_events(self.events) if strip_volatile \
            else self.events
        return "".join(json.dumps(e.to_dict(), sort_keys=True) + "\n"
                       for e in events)

    def write_chrome(self, path: str, strip_volatile: bool = False) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome(strip_volatile=strip_volatile), f)
            f.write("\n")

    def write_jsonl(self, path: str, strip_volatile: bool = False) -> None:
        with open(path, "w") as f:
            f.write(self.jsonl(strip_volatile=strip_volatile))

    # -- summary ------------------------------------------------------------

    def summary(self) -> dict:
        spans = [e for e in self.events if e.ph == "X"]
        by_track: Dict[str, dict] = {}
        for e in spans:
            row = by_track.setdefault(e.track, dict(spans=0, total_s=0.0))
            row["spans"] += 1
            row["total_s"] += e.dur
        return dict(events=len(self.events), spans=len(spans),
                    instants=sum(1 for e in self.events if e.ph == "i"),
                    tracks={t: by_track[t] for t in sorted(by_track)})
