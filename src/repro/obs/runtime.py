"""The on/off switch every instrumented call site checks.

The whole zero-overhead-when-disabled contract lives here: instrumented
code does

    ob = runtime.active()
    if ob is not None:
        ob.metrics.counter(...).inc(...)
        ob.trace.span(...)

so the disabled cost is one module-global read returning ``None`` — no
allocation, no method call, no event object.  tests/test_obs.py enforces
this by installing an :class:`Observability` whose trace/metrics raise on
any use and running the serving path with obs *disabled*.

``instrument()`` installs a session (optionally bound to a ``FakeClock``
so a virtual-time simulation yields a deterministic event log);
``disable()`` removes it; ``instrumented()`` is the context-manager form.
Only one session is active at a time — the last ``instrument()`` wins,
which is the right semantics for a CLI process.
"""
from __future__ import annotations

import contextlib
import time
from typing import List, Optional

from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Trace

__all__ = [
    "Observability", "active", "instrument", "install", "disable",
    "instrumented", "export",
]


class Observability:
    """One instrumentation session: a metrics registry + a trace + any
    kernel profiles attached along the way, sharing one clock domain."""

    def __init__(self, clock=None):
        self.clock = clock
        self.metrics = MetricsRegistry()
        self.trace = Trace(clock=clock)
        self.profiles: List = []          # TaskProfile rows (obs.profile)
        self.health = None                # HealthMonitor, when alerting is on

    def now(self) -> float:
        return self.clock.now() if self.clock is not None \
            else time.monotonic()

    def set_clock(self, clock) -> None:
        """Re-bind the clock domain (a runner that builds its ``FakeClock``
        after instrumentation was requested calls this before recording)."""
        self.clock = clock
        self.trace.clock = clock


_ACTIVE: Optional[Observability] = None


def active() -> Optional[Observability]:
    """The installed session, or None — THE hot-path check."""
    return _ACTIVE


def instrument(clock=None) -> Observability:
    """Install (and return) a fresh observability session."""
    global _ACTIVE
    _ACTIVE = Observability(clock=clock)
    return _ACTIVE


def install(ob: Optional[Observability]) -> Optional[Observability]:
    """(Re)install a specific session (or ``None`` to uninstall) — how the
    ``overhead_obs`` benchmark toggles one accumulating session on and off
    around interleave-timed calls, and how callers restore whatever was
    active before they borrowed the switch."""
    global _ACTIVE
    _ACTIVE = ob
    return ob


def disable() -> Optional[Observability]:
    """Uninstall the session; returns it so callers can still export."""
    global _ACTIVE
    ob, _ACTIVE = _ACTIVE, None
    return ob


@contextlib.contextmanager
def instrumented(clock=None):
    """``with obs.instrumented() as ob: ...`` — always uninstalls."""
    ob = instrument(clock=clock)
    try:
        yield ob
    finally:
        disable()


def export(ob: Observability, trace_out: Optional[str] = None,
           metrics_out: Optional[str] = None,
           jsonl_out: Optional[str] = None,
           strip_volatile: bool = False) -> dict:
    """Write the session's artifacts; returns {kind: path} for what was
    written.  ``trace_out`` gets Chrome ``trace_event`` JSON (Perfetto),
    ``jsonl_out`` the line-per-event log, ``metrics_out`` Prometheus text."""
    written = {}
    if trace_out:
        ob.trace.write_chrome(trace_out, strip_volatile=strip_volatile)
        written["trace"] = trace_out
    if jsonl_out:
        ob.trace.write_jsonl(jsonl_out, strip_volatile=strip_volatile)
        written["jsonl"] = jsonl_out
    if metrics_out:
        with open(metrics_out, "w") as f:
            f.write(ob.metrics.render_text())
        written["metrics"] = metrics_out
    return written
