"""Deterministic synthetic data pipelines with checkpointable state.

CIFAR-10 is not shipped in the container (see DESIGN.md §2), so training
exercises use a synthetic dataset that is (a) deterministic given (seed,
step) — restarts are bitwise reproducible, (b) learnable — labels are a
function of the input, so loss decreases and accuracy rises above chance.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class PipelineState:
    seed: int
    step: int

    def to_dict(self):
        return dict(seed=self.seed, step=self.step)

    @classmethod
    def from_dict(cls, d):
        return cls(seed=int(d["seed"]), step=int(d["step"]))


class SyntheticCifar:
    """32x32x3 images whose label is derivable from class-dependent color
    statistics + frozen random templates — a task a small CNN can learn."""

    def __init__(self, batch_size: int, seed: int = 0, num_classes: int = 10):
        self.batch_size = batch_size
        self.num_classes = num_classes
        self.state = PipelineState(seed, 0)
        rng = np.random.RandomState(seed ^ 0x5EED)
        self.templates = rng.uniform(0, 1, (num_classes, 32, 32, 3)).astype(
            np.float32)

    def next(self):
        rng = np.random.RandomState(
            (self.state.seed * 1_000_003 + self.state.step) % (2 ** 31))
        labels = rng.randint(0, self.num_classes, self.batch_size)
        noise = rng.uniform(0, 1, (self.batch_size, 32, 32, 3)).astype(
            np.float32)
        images = 0.6 * self.templates[labels] + 0.4 * noise
        self.state.step += 1
        return dict(images=np.clip(images, 0, 0.999),
                    labels=labels.astype(np.int32))


class SyntheticTokens:
    """LM token stream: next token = (5*t + 7) % vocab with noise, so the
    model can reduce loss well below uniform."""

    def __init__(self, batch_size: int, seq_len: int, vocab: int,
                 seed: int = 0):
        self.batch_size, self.seq_len, self.vocab = batch_size, seq_len, vocab
        self.state = PipelineState(seed, 0)

    def next(self):
        rng = np.random.RandomState(
            (self.state.seed * 1_000_003 + self.state.step) % (2 ** 31))
        start = rng.randint(0, self.vocab, (self.batch_size, 1))
        ar = np.arange(self.seq_len)[None, :]
        tokens = (start + 5 * ar + 7) % self.vocab
        flip = rng.uniform(size=tokens.shape) < 0.05
        tokens = np.where(flip, rng.randint(0, self.vocab, tokens.shape),
                          tokens)
        labels = np.concatenate(
            [tokens[:, 1:], -np.ones((self.batch_size, 1), np.int64)], axis=1)
        self.state.step += 1
        return dict(tokens=tokens.astype(np.int32),
                    labels=labels.astype(np.int32))
