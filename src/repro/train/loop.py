"""Fault-tolerant training loop.

Production posture (DESIGN.md §5):
  * periodic async checkpoints (atomic; retention);
  * auto-resume from the latest checkpoint, including the data-pipeline
    state, so restarts are bitwise reproducible;
  * SIGTERM/SIGINT -> checkpoint-now then clean exit (preemption handling);
  * step watchdog: a step exceeding ``watchdog_s`` is logged as a straggler
    / hang and (optionally) aborts so the scheduler can restart the job —
    on multi-pod SPMD a hung peer manifests exactly this way.
"""
from __future__ import annotations

import dataclasses
import signal
import threading
import time
from typing import Callable, Optional

import jax

from repro.train import checkpoint as ckpt_lib


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    keep: int = 3
    watchdog_s: float = 0.0          # 0 = disabled
    abort_on_hang: bool = False
    log_every: int = 10


class Watchdog:
    def __init__(self, limit_s: float, abort: bool, log):
        self.limit_s, self.abort, self.log = limit_s, abort, log
        self._timer = None
        self.fired = 0

    def _fire(self):
        self.fired += 1
        self.log(f"[watchdog] step exceeded {self.limit_s}s — straggler or "
                 f"hung collective; {'aborting' if self.abort else 'noting'}")
        if self.abort:
            import os
            os._exit(42)  # let the scheduler restart from the last checkpoint

    def arm(self):
        if self.limit_s <= 0:
            return
        self.disarm()
        self._timer = threading.Timer(self.limit_s, self._fire)
        self._timer.daemon = True
        self._timer.start()

    def disarm(self):
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None


def run(loop_cfg: LoopConfig, *, params, opt_state, train_step: Callable,
        pipeline, shardings=None, log: Callable = print):
    """Generic loop: train_step(params, opt_state, step, batch)."""
    start_step = 0
    if loop_cfg.ckpt_dir and ckpt_lib.latest_steps(loop_cfg.ckpt_dir):
        (params, opt_state), start_step, extra = ckpt_lib.restore(
            loop_cfg.ckpt_dir, (params, opt_state), shardings=shardings)
        if "pipeline" in extra:
            pipeline.state = type(pipeline.state).from_dict(extra["pipeline"])
        log(f"[resume] restored step {start_step}")
        start_step += 1

    stop = {"now": False}

    def _sig(_signum, _frame):
        log("[signal] preemption — checkpointing and exiting")
        stop["now"] = True

    prev_int = signal.signal(signal.SIGINT, _sig)
    prev_term = signal.signal(signal.SIGTERM, _sig)
    wd = Watchdog(loop_cfg.watchdog_s, loop_cfg.abort_on_hang, log)
    metrics = {}
    step = start_step
    try:
        t_loop = time.time()
        for step in range(start_step, loop_cfg.total_steps):
            batch = pipeline.next()
            wd.arm()
            params, opt_state, metrics = train_step(params, opt_state, step,
                                                    batch)
            jax.block_until_ready(metrics)
            wd.disarm()
            if step % loop_cfg.log_every == 0:
                m = {k: float(v) for k, v in metrics.items()}
                log(f"[step {step}] {m} ({time.time()-t_loop:.1f}s)")
            if (loop_cfg.ckpt_dir and loop_cfg.ckpt_every and
                    (step + 1) % loop_cfg.ckpt_every == 0):
                ckpt_lib.save_async(
                    loop_cfg.ckpt_dir, step, (params, opt_state),
                    extra=dict(pipeline=pipeline.state.to_dict()),
                    keep=loop_cfg.keep)
            if stop["now"]:
                break
    finally:
        wd.disarm()
        signal.signal(signal.SIGINT, prev_int)
        signal.signal(signal.SIGTERM, prev_term)
    if loop_cfg.ckpt_dir:
        ckpt_lib.save(loop_cfg.ckpt_dir, step, (params, opt_state),
                      extra=dict(pipeline=pipeline.state.to_dict()),
                      keep=loop_cfg.keep)
        ckpt_lib.wait_pending()
    return params, opt_state, metrics
