"""Optimizers (no external deps).

* ``sgdm``  — SGD + momentum + cosine annealing (the paper's training recipe).
* ``adamw`` — AdamW with optional **int8 pow2-block-quantized moments**
  (core.quant.block_quantize): the paper's quantization scheme applied to
  optimizer state, which is what lets the 340B/671B cells fit the pod
  (DESIGN.md §5).  Moments are dequantized, updated, requantized each step —
  error feedback is implicit in the pow2 grid (quantization of m/v, not of
  the update).

API: opt = make(name, **hp); state = opt.init(params);
     params, state = opt.update(grads, state, params, step)
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant as Q


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable


def cosine_lr(base_lr: float, total_steps: int, warmup: int = 0):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, (step + 1) / jnp.maximum(warmup, 1))
        t = jnp.clip((step - warmup) / jnp.maximum(total_steps - warmup, 1),
                     0.0, 1.0)
        return base_lr * warm * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return lr


def sgdm(lr=0.1, momentum=0.9, weight_decay=1e-4, total_steps=1000,
         warmup=0):
    sched = cosine_lr(lr, total_steps, warmup)

    def init(params):
        return dict(mu=jax.tree_util.tree_map(jnp.zeros_like, params))

    def update(grads, state, params, step):
        lr_t = sched(step)

        def upd(g, m, p):
            g = g + weight_decay * p
            m = momentum * m + g
            return p - lr_t * m, m

        out = jax.tree_util.tree_map(upd, grads, state["mu"], params)
        new_p = jax.tree_util.tree_map(lambda t: t[0], out,
                                       is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                       is_leaf=lambda t: isinstance(t, tuple))
        return new_p, dict(mu=new_m)

    return Optimizer(init, update)


def adamw(lr=3e-4, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          total_steps=10_000, warmup=200, int8_state=False,
          state_block=128):
    sched = cosine_lr(lr, total_steps, warmup)

    def _q(x):
        if not int8_state or x.size < state_block:
            return x
        return Q.block_quantize(x.astype(jnp.float32), block=state_block)

    def _dq(x):
        if isinstance(x, Q.BlockQuantized):
            return Q.block_dequantize(x, block=state_block)
        return x

    def init(params):
        zeros = jax.tree_util.tree_map(
            lambda p: _q(jnp.zeros(p.shape, jnp.float32)), params)
        zeros2 = jax.tree_util.tree_map(
            lambda p: _q(jnp.zeros(p.shape, jnp.float32)), params)
        return dict(m=zeros, v=zeros2)

    def update(grads, state, params, step):
        lr_t = sched(step)
        c1 = 1 - b1 ** (jnp.asarray(step, jnp.float32) + 1)
        c2 = 1 - b2 ** (jnp.asarray(step, jnp.float32) + 1)
        is_q = lambda t: isinstance(t, Q.BlockQuantized)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            mf = b1 * _dq(m) + (1 - b1) * gf
            vf = b2 * _dq(v) + (1 - b2) * gf * gf
            u = (mf / c1) / (jnp.sqrt(vf / c2) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            newp = (p.astype(jnp.float32) - lr_t * u).astype(p.dtype)
            return newp, _q(mf), _q(vf)

        flat_g, tree = jax.tree_util.tree_flatten(grads)
        flat_m = jax.tree_util.tree_flatten(state["m"], is_leaf=is_q)[0]
        flat_v = jax.tree_util.tree_flatten(state["v"], is_leaf=is_q)[0]
        flat_p = jax.tree_util.tree_flatten(params)[0]
        outs = [upd(g, m, v, p) for g, m, v, p
                in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = jax.tree_util.tree_unflatten(tree, [o[0] for o in outs])
        new_m = jax.tree_util.tree_unflatten(tree, [o[1] for o in outs])
        new_v = jax.tree_util.tree_unflatten(tree, [o[2] for o in outs])
        return new_p, dict(m=new_m, v=new_v)

    return Optimizer(init, update)


def make(name: str, **hp) -> Optimizer:
    if name == "sgdm":
        return sgdm(**hp)
    if name == "adamw":
        return adamw(**hp)
    raise ValueError(name)
