"""Checkpointing — self-contained (no orbax/tensorstore), built for fault
tolerance and elastic restarts:

* **atomic**: written to ``<dir>/tmp.<step>`` then renamed to ``step_<n>``;
  a crash mid-write never corrupts the latest checkpoint.
* **manifest'd**: manifest.json stores the pytree structure, shapes, dtypes
  and per-leaf SHA256 — restore verifies integrity.
* **async**: ``save_async`` snapshots to host memory synchronously (cheap)
  and writes in a daemon thread so the train loop keeps stepping.
* **elastic / reshard-on-restore**: leaves are stored unsharded (gathered);
  ``restore(..., shardings=...)`` device_puts onto ANY mesh, so a job can
  resume on a different topology (DESIGN.md §5).
* **retention**: keep the last ``keep`` checkpoints.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_SEP = "/"


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, jax.tree_util.tree_structure(tree)


def _tree_paths(tree):
    leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [_SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                      for p in path) for path, _ in leaves]


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[dict] = None,
         keep: int = 3):
    """Synchronous atomic save."""
    flat, _ = _flatten(tree)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = dict(step=step, extra=extra or {}, leaves={})
    for i, (key, leaf) in enumerate(sorted(flat.items())):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = dict(
            file=fname, shape=list(arr.shape), dtype=str(arr.dtype),
            sha256=hashlib.sha256(arr.tobytes()).hexdigest())
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _retain(ckpt_dir, keep)
    return final


_PENDING: list = []


def save_async(ckpt_dir: str, step: int, tree: Any,
               extra: Optional[dict] = None, keep: int = 3):
    """Snapshot to host now, write on a daemon thread."""
    host_tree = jax.tree_util.tree_map(
        lambda x: np.asarray(jax.device_get(x)), tree)
    t = threading.Thread(target=save, args=(ckpt_dir, step, host_tree),
                         kwargs=dict(extra=extra, keep=keep), daemon=True)
    t.start()
    _PENDING.append(t)
    return t


def wait_pending():
    for t in list(_PENDING):
        t.join()
        _PENDING.remove(t)


def _retain(ckpt_dir: str, keep: int):
    steps = sorted(latest_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"),
                      ignore_errors=True)


def latest_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def restore(ckpt_dir: str, target_tree: Any, step: Optional[int] = None,
            shardings: Any = None, verify: bool = True):
    """Restore into the structure of ``target_tree``; device_put each leaf
    onto ``shardings`` (same structure) if given — works on any mesh."""
    steps = latest_steps(ckpt_dir)
    if not steps:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    step = steps[-1] if step is None else step
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    keys = _tree_paths(target_tree)
    flat_shard = (jax.tree_util.tree_leaves(shardings)
                  if shardings is not None else [None] * len(keys))
    leaves = []
    for key, shard in zip(keys, flat_shard):
        meta = manifest["leaves"][key]
        arr = np.load(os.path.join(path, meta["file"]))
        if verify:
            h = hashlib.sha256(arr.tobytes()).hexdigest()
            if h != meta["sha256"]:
                raise IOError(f"checksum mismatch for {key} in {path}")
        leaves.append(jax.device_put(arr, shard) if shard is not None
                      else jax.numpy.asarray(arr))
    treedef = jax.tree_util.tree_structure(target_tree)
    return jax.tree_util.tree_unflatten(treedef, leaves), step, \
        manifest.get("extra", {})
