from repro.configs.base import (ARCH_IDS, SHAPES, ModelConfig, ShapeSpec,  # noqa: F401
                                get_config, get_smoke_config, input_specs)
