"""nemotron-4-340b  [dense] 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000 — squared-ReLU MLP, head_dim=192.  [arXiv:2402.16819; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b", family="dense",
    num_layers=96, d_model=18432, num_heads=96, num_kv_heads=8, head_dim=192,
    d_ff=73728, vocab_size=256_000,
    mlp_type="relu2",
)


def smoke() -> ModelConfig:
    return CONFIG.with_(num_layers=2, d_model=96, num_heads=4, num_kv_heads=2,
                        head_dim=24, d_ff=256, vocab_size=512,
                        dtype="float32", param_dtype="float32",
                        attn_chunk=0, loss_chunk=16)
