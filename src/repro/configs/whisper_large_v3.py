"""whisper-large-v3  [audio] enc-dec, 32L each, d_model=1280 20H (MHA kv=20)
d_ff=5120 vocab=51866 — conv frontend is a STUB: input_specs() provides
precomputed frame embeddings (B, encoder_len, d).  [arXiv:2212.04356]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio",
    num_layers=32, encoder_layers=32, d_model=1280, num_heads=20,
    num_kv_heads=20, head_dim=64, d_ff=5120, vocab_size=51_866,
    mlp_type="silu", norm_type="layernorm", use_rope=False,
    encoder_len=1500,
)


def smoke() -> ModelConfig:
    return CONFIG.with_(num_layers=2, encoder_layers=2, d_model=64,
                        num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
                        vocab_size=512, encoder_len=32,
                        dtype="float32", param_dtype="float32",
                        attn_chunk=0, loss_chunk=16)
