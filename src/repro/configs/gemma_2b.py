"""gemma-2b  [dense] 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000
GeGLU, head_dim=256, embedding scaling.  [arXiv:2403.08295; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b", family="dense",
    num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=256_000,
    mlp_type="geglu", tie_embeddings=True, emb_scale=True,
)


def smoke() -> ModelConfig:
    return CONFIG.with_(num_layers=2, d_model=64, num_heads=4, num_kv_heads=1,
                        head_dim=16, d_ff=128, vocab_size=512,
                        dtype="float32", param_dtype="float32",
                        attn_chunk=0, loss_chunk=16)
