"""falcon-mamba-7b  [ssm] 64L d_model=4096 attention-free, vocab=65024,
ssm_state=16 (mamba1: d_inner=8192, dt_rank=256, conv k=4).
[arXiv:2410.05355; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    num_layers=64, d_model=4096, d_ff=0, vocab_size=65_024,
    attn_type="none", use_rope=False,
    ssm_state=16, d_inner=8192, dt_rank=256, conv_kernel=4, mamba_version=1,
)


def smoke() -> ModelConfig:
    return CONFIG.with_(num_layers=2, d_model=64, d_inner=128, dt_rank=8,
                        ssm_state=4, vocab_size=512,
                        dtype="float32", param_dtype="float32", loss_chunk=16)
