"""llama3.2-3b  [dense] 28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256
[hf:meta-llama/Llama-3.2-3B; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b", family="dense",
    num_layers=28, d_model=3072, num_heads=24, num_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=128_256,
    mlp_type="silu", rope_theta=500_000.0, tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return CONFIG.with_(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                        head_dim=16, d_ff=128, vocab_size=512,
                        dtype="float32", param_dtype="float32",
                        attn_chunk=0, loss_chunk=16)
