"""granite-8b  [dense] 36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152
llama-arch, code model.  [arXiv:2405.04324; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b", family="dense",
    num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=49_152,
    mlp_type="silu",
)


def smoke() -> ModelConfig:
    return CONFIG.with_(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                        head_dim=16, d_ff=128, vocab_size=512,
                        dtype="float32", param_dtype="float32",
                        attn_chunk=0, loss_chunk=16)
