"""deepseek-v3-671b  [moe] 61L d_model=7168 128H, MLA (q_lora 1536, kv_lora
512, nope 128, rope 64, v 128), MoE: 1 shared + 256 routed top-8 (expert
d_ff=2048), first 3 layers dense (d_ff=18432), MTP depth 1, vocab=129280.
[arXiv:2412.19437; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
    head_dim=128, d_ff=18432, vocab_size=129_280,
    mlp_type="silu", attn_type="mla",
    q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
    v_head_dim=128,
    num_experts=256, num_shared_experts=1, top_k=8, moe_d_ff=2048,
    first_dense_layers=3, mtp_depth=1,
)


def smoke() -> ModelConfig:
    return CONFIG.with_(num_layers=3, first_dense_layers=1, d_model=64,
                        num_heads=4, num_kv_heads=4,
                        q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                        qk_rope_dim=8, v_head_dim=16, head_dim=16,
                        d_ff=128, moe_d_ff=32, num_experts=8, top_k=2,
                        vocab_size=512, mtp_depth=1,
                        dtype="float32", param_dtype="float32",
                        attn_chunk=0, loss_chunk=16)
