"""internvl2-1b  [vlm] 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655
InternViT frontend is a STUB: input_specs() provides precomputed patch
embeddings (B, num_patches, d) that occupy the first token slots.
[arXiv:2404.16821; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2, head_dim=64,
    d_ff=4864, vocab_size=151_655,
    mlp_type="silu", rope_theta=1_000_000.0, tie_embeddings=True,
    num_patches=256,
)


def smoke() -> ModelConfig:
    return CONFIG.with_(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                        head_dim=16, d_ff=128, vocab_size=512, num_patches=8,
                        dtype="float32", param_dtype="float32",
                        attn_chunk=0, loss_chunk=16)
