"""zamba2-7b  [hybrid] 81L d_model=3584, Mamba2 (ssm_state=64, headdim=64,
d_inner=7168) + ONE shared attention+MLP block (32H, d_ff=14336) applied after
every 6th mamba layer, vocab=32000.  [arXiv:2411.15242; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32, head_dim=112,
    d_ff=14336, vocab_size=32_000,
    mlp_type="silu",
    ssm_state=64, d_inner=7168, mamba_headdim=64, conv_kernel=4,
    mamba_version=2, shared_block_period=6,
)


def smoke() -> ModelConfig:
    return CONFIG.with_(num_layers=5, shared_block_period=2, d_model=64,
                        num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
                        d_inner=128, mamba_headdim=16, ssm_state=8,
                        vocab_size=512,
                        dtype="float32", param_dtype="float32",
                        attn_chunk=0, loss_chunk=16)
