"""Architecture/shape configuration system.

Every assigned architecture is a ``ModelConfig`` in ``repro.configs.<id>``;
``get_config(name)`` resolves it.  Shape cells (train_4k / prefill_32k /
decode_32k / long_500k) are ``ShapeSpec``s; ``input_specs()`` produces
``jax.ShapeDtypeStruct`` stand-ins for the dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm | resnet
    num_layers: int
    d_model: int
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    mlp_type: str = "silu"           # silu | geglu | relu2
    attn_type: str = "gqa"           # gqa | mla | none
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm
    rope_theta: float = 10_000.0
    use_rope: bool = True
    tie_embeddings: bool = False
    emb_scale: bool = False          # gemma: scale embeddings by sqrt(d)
    sliding_window: int = 0          # SWA window (0 = full attention)
    logit_softcap: float = 0.0
    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0      # deepseek: first k layers are dense
    moe_capacity_factor: float = 1.25
    # --- MLA (deepseek) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    mtp_depth: int = 0               # deepseek multi-token-prediction heads
    # --- SSM ---
    ssm_state: int = 0
    d_inner: int = 0
    dt_rank: int = 0
    conv_kernel: int = 4
    mamba_version: int = 1           # 1 (falcon-mamba) | 2 (zamba2 SSD)
    mamba_headdim: int = 64          # mamba2 only
    shared_block_period: int = 0     # zamba2: shared attn block every N layers
    # --- enc-dec / multimodal (whisper, internvl2) ---
    encoder_layers: int = 0          # whisper: encoder depth (== num_layers)
    encoder_len: int = 1500          # stub frame/patch sequence length
    num_patches: int = 0             # internvl2 patch embedding count
    # --- numerics / technique (paper) ---
    dtype: str = "bfloat16"          # activation/compute dtype
    param_dtype: str = "bfloat16"
    quant: str = "none"              # none | qat | int8w  (paper pow2-int8)
    kv_cache_dtype: str = "bfloat16"  # or "int8" (paper scheme on the cache)
    residual_fusion: bool = True     # paper add-fold on the residual stream
    # --- schedule / memory ---
    kv_shard_model: bool = False   # shard KV-cache head_dim over 'model'
    seq_shard: bool = False        # Megatron-SP: shard activations' seq dim
    remat: bool = True
    remat_policy: str = "dots"       # dots | nothing (recompute everything)
    scan_layers: bool = True
    attn_chunk: int = 512            # flash-style chunking for long seq
    loss_chunk: int = 1024           # chunked softmax-xent
    moe_impl: str = "grouped"        # grouped (sort+scan) | dense (ref)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- derived ----
    @property
    def qk_head_dim(self) -> int:
        if self.attn_type == "mla":
            return self.qk_nope_dim + self.qk_rope_dim
        return self.head_dim

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def supports_long_context(self) -> bool:
        """long_500k runs only for sub-quadratic sequence mixers."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def supports_shape(self, shape: str) -> bool:
        if shape == "long_500k":
            return self.supports_long_context()
        return True


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{name.replace('-', '_').replace('.', '_')}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{name.replace('-', '_').replace('.', '_')}")
    return mod.smoke()


ARCH_IDS = [
    "gemma-2b",
    "llama3.2-3b",
    "nemotron-4-340b",
    "granite-8b",
    "whisper-large-v3",
    "internvl2-1b",
    "falcon-mamba-7b",
    "mixtral-8x22b",
    "deepseek-v3-671b",
    "zamba2-7b",
]


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; weak-type-correct, no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeSpec, sharding=None) -> dict:
    """Model inputs for one shape cell.  ``sharding`` is an optional callable
    PartitionSpec-factory: sharding(logical_axes) -> jax.sharding.Sharding."""
    B, S = shape.global_batch, shape.seq_len

    def sds(shp, dtype, axes):
        sh = sharding(shp, axes) if sharding is not None else None
        return jax.ShapeDtypeStruct(shp, dtype, sharding=sh)

    i32, f = jnp.int32, cfg.compute_dtype
    if shape.kind == "train":
        specs = dict(
            tokens=sds((B, S), i32, ("batch", "seq")),
            labels=sds((B, S), i32, ("batch", "seq")),
        )
        if cfg.family == "audio":
            # conv-frontend STUB: precomputed frame embeddings for the encoder
            specs["frames"] = sds((B, cfg.encoder_len, cfg.d_model), f,
                                  ("batch", "seq", "embed"))
        if cfg.family == "vlm":
            specs["patches"] = sds((B, cfg.num_patches, cfg.d_model), f,
                                   ("batch", "seq", "embed"))
        return specs
    if shape.kind == "prefill":
        specs = dict(tokens=sds((B, S), i32, ("batch", "seq")))
        if cfg.family == "audio":
            specs["frames"] = sds((B, cfg.encoder_len, cfg.d_model), f,
                                  ("batch", "seq", "embed"))
        if cfg.family == "vlm":
            specs["patches"] = sds((B, cfg.num_patches, cfg.d_model), f,
                                   ("batch", "seq", "embed"))
        return specs
    # decode: one new token against a seq_len-deep cache/state
    specs = dict(
        tokens=sds((B, 1), i32, ("batch", None)),
        pos=sds((B,), i32, ("batch",)),
        cache=cache_specs(cfg, B, S, sds),
    )
    return specs


def cache_specs(cfg: ModelConfig, B: int, S: int, sds) -> dict:
    """Decode-state stand-ins.  SWA bounds the cache to the window (the reason
    mixtral runs long_500k); SSM state is O(1) in S."""
    kv_dt = jnp.int8 if cfg.kv_cache_dtype == "int8" else cfg.compute_dtype
    f32 = jnp.float32
    out = {}
    L = cfg.num_layers
    S_kv = min(S, cfg.sliding_window) if cfg.sliding_window else S

    if cfg.family in ("ssm",):
        out["ssm_state"] = sds((L, B, cfg.d_inner, cfg.ssm_state), f32,
                               (None, "batch", "heads", None))
        out["conv_state"] = sds((L, B, cfg.conv_kernel - 1, cfg.d_inner),
                                cfg.compute_dtype, (None, "batch", None, "heads"))
        return out
    if cfg.family == "hybrid":
        nh = cfg.d_inner // cfg.mamba_headdim
        out["ssm_state"] = sds((L, B, nh, cfg.mamba_headdim, cfg.ssm_state),
                               f32, (None, "batch", "heads", None, None))
        # mamba2 convolves x, B and C jointly -> d_inner + 2*N channels
        out["conv_state"] = sds(
            (L, B, cfg.conv_kernel - 1, cfg.d_inner + 2 * cfg.ssm_state),
            cfg.compute_dtype, (None, "batch", None, "heads"))
        # the single shared attention block's KV cache
        n_shared = L // cfg.shared_block_period
        out["k"] = sds((n_shared, B, S_kv, cfg.num_kv_heads, cfg.head_dim),
                       kv_dt, (None, "batch", "seq", "heads", None))
        out["v"] = sds((n_shared, B, S_kv, cfg.num_kv_heads, cfg.head_dim),
                       kv_dt, (None, "batch", "seq", "heads", None))
        return out
    if cfg.attn_type == "mla":
        # MLA caches the compressed latent + rope key only (paper-faithful
        # int8 quantization applies to this latent as well)
        hd_ax = "embed" if cfg.kv_shard_model else None
        out["ckv"] = sds((L, B, S_kv, cfg.kv_lora_rank), kv_dt,
                         (None, "batch", "seq", hd_ax))
        out["krope"] = sds((L, B, S_kv, cfg.qk_rope_dim), kv_dt,
                           (None, "batch", "seq", None))
        return out
    # GQA/MQA transformer KV cache; optionally shard head_dim over 'model'
    # (kv head counts are rarely divisible by 16, head_dim always is)
    hd_ax = "embed" if cfg.kv_shard_model else None
    out["k"] = sds((L, B, S_kv, cfg.num_kv_heads, cfg.head_dim), kv_dt,
                   (None, "batch", "seq", None, hd_ax))
    out["v"] = sds((L, B, S_kv, cfg.num_kv_heads, cfg.head_dim), kv_dt,
                   (None, "batch", "seq", None, hd_ax))
    if cfg.family == "audio":
        # cross-attention K/V over stub encoder states (computed at prefill)
        out["xk"] = sds((L, B, cfg.encoder_len, cfg.num_kv_heads, cfg.head_dim),
                        kv_dt, (None, "batch", "seq", "heads", None))
        out["xv"] = sds((L, B, cfg.encoder_len, cfg.num_kv_heads, cfg.head_dim),
                        kv_dt, (None, "batch", "seq", "heads", None))
    return out
