"""mixtral-8x22b  [moe] 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, SWA window 4096.  SWA bounds the decode KV
cache, so this arch runs the long_500k cell.  [arXiv:2401.04088; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=32_768,
    mlp_type="silu", sliding_window=4096,
    num_experts=8, top_k=2, moe_d_ff=16384,
)


def smoke() -> ModelConfig:
    return CONFIG.with_(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                        head_dim=16, d_ff=128, vocab_size=512,
                        num_experts=4, top_k=2, moe_d_ff=128,
                        sliding_window=16,
                        dtype="float32", param_dtype="float32",
                        attn_chunk=0, loss_chunk=16)
