"""Batched serving engines: continuous batching over a fixed slot set.

``Engine`` serves the LM workload: requests (prompts) are admitted into free
slots; one jitted ``decode_step`` advances every active slot per tick (one
token each).  Finished slots are recycled immediately — the dataflow analogue
of the paper's stall-free pipeline: no slot waits for the longest request in
a "batch".  Prefill is per-request (token-by-token through the cache for
simplicity at test scale; the prefill_32k cell exercises the real batched
prefill path).

``ResNetEngine`` serves the paper's own workload — integer ResNet8/20 image
classification — entirely through ``repro.compile.CompiledModel``: the
optimized graph is lowered once per (backend, batch bucket) into fixed-shape
AOT executables, with the fused Pallas pipeline as the default backend, so
serving traffic takes the minimum-HBM-traffic path with zero per-tick
retracing.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, cfg, params, slots: int = 4, max_len: int = 256,
                 eos: Optional[int] = None):
        self.cfg, self.params = cfg, params
        self.slots, self.max_len, self.eos = slots, max_len, eos
        self.cache = M.init_cache(cfg, slots, max_len)
        self.pos = np.zeros((slots,), np.int32)
        self.active: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []
        self.last_tok = np.zeros((slots, 1), np.int32)

        self._step = jax.jit(
            lambda p, t, pos, c: M.decode_step(p, cfg, t, pos, c))

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i in range(self.slots):
            if self.active[i] is None and self.queue:
                req = self.queue.pop(0)
                self.active[i] = req
                if not req.prompt:
                    # empty prompt: nothing to prefill (and no logits to seed
                    # from) — start decoding from token 0 at position 0 on
                    # the next tick
                    self.pos[i] = 0
                    self.last_tok[i, 0] = 0
                    continue
                # prefill token-by-token into this slot's cache
                for j, tok in enumerate(req.prompt):
                    t = self.last_tok.copy()
                    t[i, 0] = tok
                    pos = self.pos.copy()
                    pos[i] = j
                    logits, self.cache = self._step(
                        self.params, jnp.asarray(t), jnp.asarray(pos),
                        self.cache)
                self.pos[i] = len(req.prompt)
                self.last_tok[i, 0] = int(np.argmax(
                    np.asarray(logits)[i, 0]))
                req.out.append(int(self.last_tok[i, 0]))

    def tick(self):
        """One decode step for all active slots."""
        self._admit()
        if not any(r is not None for r in self.active):
            return False
        logits, self.cache = self._step(
            self.params, jnp.asarray(self.last_tok), jnp.asarray(self.pos),
            self.cache)
        nxt = np.argmax(np.asarray(logits)[:, 0, :], axis=-1)
        for i, req in enumerate(self.active):
            if req is None:
                continue
            self.pos[i] += 1
            tok = int(nxt[i])
            req.out.append(tok)
            self.last_tok[i, 0] = tok
            if len(req.out) >= req.max_new or tok == self.eos or \
                    self.pos[i] >= self.max_len - 1:
                req.done = True
                self.active[i] = None
                self.pos[i] = 0
                self.last_tok[i, 0] = 0
        return True

    def run(self, max_ticks: int = 10_000):
        done = []
        ticks = 0
        while (self.queue or any(r is not None for r in self.active)) and \
                ticks < max_ticks:
            self.tick()
            ticks += 1
        return ticks


# ---------------------------------------------------------------------------
# Image-classification serving over the fused Pallas integer pipeline
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ImageRequest:
    rid: int
    image: np.ndarray                     # (H, W, 3) float in [0, 1)
    logits: Optional[np.ndarray] = None   # (num_classes,) once served
    label: Optional[int] = None
    done: bool = False


class ResNetEngine:
    """Image-classification engine serving entirely through
    :class:`repro.compile.CompiledModel`.

    ``compile_model`` lowers the optimized graph once per (backend, batch
    bucket) into fixed-shape AOT executables; the engine then only *selects a
    bucket, zero-pads, and runs* — no retracing ever happens on a tick (the
    model's ``trace_counts`` stay at 1 per bucket, asserted in
    tests/test_serve.py).  Backends come from the ``repro.compile`` registry:

      * ``pallas`` (default) — the fused integer kernel pipeline (stem kernel
        + one add-fold kernel per residual block).
      * ``lax-int`` (alias ``int``) — the lax reference integer graph,
        bit-identical logits, unfused dataflow.
      * ``float`` — float emulation of the integer graph on the same pow2
        grids, for A/B'ing quantization error in production.

    ``ab_backends`` compiles shadow models on additional backends; every tick
    the primary batch is replayed through each shadow and the max absolute
    logit deviation is recorded in ``ab_stats`` — a live parity probe for
    canarying a new backend against the serving one.

    ``tune`` engages the ``repro.tune`` design-space layer (a per-task
    config dict / TuneResult, or ``"auto"``/``"analytic"``/``"device"``):
    the primary model serves with the tuned kernel tiling, while the
    shadows stay untuned so the A/B probe also guards the tuner.
    """

    def __init__(self, cfg, qparams, batch: int = 8, backend: str = "pallas",
                 params=None, batch_sizes=None, ab_backends=(), tune=None):
        from repro.compile import compile_model

        del params  # legacy arg; the float backend is now self-contained
        self.cfg, self.batch = cfg, batch
        self.backend = backend
        if batch_sizes is None:
            batch_sizes = (batch,)
        if batch not in batch_sizes:
            raise ValueError(
                f"max batch {batch} must be one of batch_sizes {batch_sizes}")
        # ``tune`` flows straight into compile_model: a per-task dict /
        # TuneResult from repro.tune, or "auto"/"analytic"/"device".  Tuning
        # only reschedules the kernels — logits are bit-identical — so the
        # shadows stay untuned: the A/B probe then also guards the tuner.
        self.model = compile_model(cfg, qparams, backend=backend,
                                   batch_sizes=batch_sizes, tune=tune)
        self.tuning = self.model.tuning
        self.qparams = self.model.params
        self.shadows = {name: compile_model(cfg, qparams, backend=name,
                                            batch_sizes=batch_sizes)
                        for name in ab_backends}
        self.ab_stats = {name: [] for name in self.shadows}
        self.queue: List[ImageRequest] = []
        self.served = 0

    def submit(self, req: ImageRequest):
        """Enqueue one request.  Shape is validated here — every compiled
        executable is fixed-shape, so a mismatched image can never be
        batched; rejecting at submit keeps ``tick`` total."""
        expect = (self.cfg.img, self.cfg.img, 3)
        shape = tuple(np.shape(req.image))
        if shape != expect:
            raise ValueError(
                f"request {req.rid}: image shape {shape} does not match the "
                f"compiled input shape {expect} for {self.cfg.name}")
        self.queue.append(req)

    def tick(self) -> bool:
        """Serve one batch; returns False when the queue is empty."""
        if not self.queue:
            return False
        reqs = self.queue[:self.batch]
        del self.queue[:len(reqs)]
        imgs = np.stack([np.asarray(r.image, np.float32) for r in reqs])
        logits = np.asarray(self.model(imgs))
        for name, shadow in self.shadows.items():
            dev = np.max(np.abs(np.asarray(shadow(imgs)) - logits))
            self.ab_stats[name].append(float(dev))
        for i, r in enumerate(reqs):
            r.logits = logits[i]
            r.label = int(np.argmax(logits[i]))
            r.done = True
        self.served += len(reqs)
        return True

    def run(self, max_ticks: int = 10_000) -> int:
        ticks = 0
        while self.queue and ticks < max_ticks:
            self.tick()
            ticks += 1
        return ticks
