"""Batched serving engines: continuous batching over a fixed slot set.

``Engine`` serves the LM workload: requests (prompts) are admitted into free
slots; one jitted ``decode_step`` advances every active slot per tick (one
token each).  Finished slots are recycled immediately — the dataflow analogue
of the paper's stall-free pipeline: no slot waits for the longest request in
a "batch".  Prefill is per-request (token-by-token through the cache for
simplicity at test scale; the prefill_32k cell exercises the real batched
prefill path).

``ResNetEngine`` serves the paper's own workload — integer ResNet8/20 image
classification — with the fused Pallas pipeline (models.resnet.pallas_forward)
as the default backend: every residual block runs through the add-fold kernel,
so serving traffic takes the minimum-HBM-traffic path by default.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, cfg, params, slots: int = 4, max_len: int = 256,
                 eos: Optional[int] = None):
        self.cfg, self.params = cfg, params
        self.slots, self.max_len, self.eos = slots, max_len, eos
        self.cache = M.init_cache(cfg, slots, max_len)
        self.pos = np.zeros((slots,), np.int32)
        self.active: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []
        self.last_tok = np.zeros((slots, 1), np.int32)

        self._step = jax.jit(
            lambda p, t, pos, c: M.decode_step(p, cfg, t, pos, c))

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i in range(self.slots):
            if self.active[i] is None and self.queue:
                req = self.queue.pop(0)
                self.active[i] = req
                # prefill token-by-token into this slot's cache
                for j, tok in enumerate(req.prompt):
                    t = self.last_tok.copy()
                    t[i, 0] = tok
                    pos = self.pos.copy()
                    pos[i] = j
                    logits, self.cache = self._step(
                        self.params, jnp.asarray(t), jnp.asarray(pos),
                        self.cache)
                self.pos[i] = len(req.prompt)
                self.last_tok[i, 0] = int(np.argmax(
                    np.asarray(logits)[i, 0]))
                req.out.append(int(self.last_tok[i, 0]))

    def tick(self):
        """One decode step for all active slots."""
        self._admit()
        if not any(r is not None for r in self.active):
            return False
        logits, self.cache = self._step(
            self.params, jnp.asarray(self.last_tok), jnp.asarray(self.pos),
            self.cache)
        nxt = np.argmax(np.asarray(logits)[:, 0, :], axis=-1)
        for i, req in enumerate(self.active):
            if req is None:
                continue
            self.pos[i] += 1
            tok = int(nxt[i])
            req.out.append(tok)
            self.last_tok[i, 0] = tok
            if len(req.out) >= req.max_new or tok == self.eos or \
                    self.pos[i] >= self.max_len - 1:
                req.done = True
                self.active[i] = None
                self.pos[i] = 0
                self.last_tok[i, 0] = 0
        return True

    def run(self, max_ticks: int = 10_000):
        done = []
        ticks = 0
        while (self.queue or any(r is not None for r in self.active)) and \
                ticks < max_ticks:
            self.tick()
            ticks += 1
        return ticks


# ---------------------------------------------------------------------------
# Image-classification serving over the fused Pallas integer pipeline
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ImageRequest:
    rid: int
    image: np.ndarray                     # (H, W, 3) float in [0, 1)
    logits: Optional[np.ndarray] = None   # (num_classes,) once served
    label: Optional[int] = None
    done: bool = False


class ResNetEngine:
    """Fixed-batch image-classification engine.

    Queued requests are drained in arrival order into fixed-size batches
    (short batches are zero-padded so every tick hits the same compiled
    executable — no shape-polymorphic recompiles on the serving path) and run
    through one of three interchangeable backends over the same quantized
    parameter set:

      * ``pallas`` (default) — models.resnet.pallas_forward, the fused
        integer pipeline: stem kernel + one add-fold kernel per block.
      * ``int``    — models.resnet.int_forward, the lax reference integer
        graph (bit-identical logits, unfused dataflow).
      * ``float``  — models.resnet.forward on QAT float params, for A/B'ing
        quantization error in production (requires ``params``).
    """

    def __init__(self, cfg, qparams, batch: int = 8, backend: str = "pallas",
                 params=None):
        from repro.models import resnet as RN

        self.cfg, self.qparams, self.batch = cfg, qparams, batch
        self.backend = backend
        self.queue: List[ImageRequest] = []
        self.served = 0
        if backend == "pallas":
            self._fwd = lambda x: RN.pallas_forward(qparams, cfg, x)
        elif backend == "int":
            self._fwd = lambda x: RN.int_forward(qparams, cfg, x)
        elif backend == "float":
            if params is None:
                raise ValueError("backend='float' needs the QAT params")
            self._fwd = lambda x: RN.forward(params, cfg, x, train=False)
        else:
            raise ValueError(f"unknown backend {backend!r}")

    def submit(self, req: ImageRequest):
        self.queue.append(req)

    def tick(self) -> bool:
        """Serve one batch; returns False when the queue is empty."""
        if not self.queue:
            return False
        reqs = self.queue[:self.batch]
        del self.queue[:len(reqs)]
        imgs = np.zeros((self.batch,) + reqs[0].image.shape, np.float32)
        for i, r in enumerate(reqs):
            imgs[i] = r.image
        logits = np.asarray(self._fwd(jnp.asarray(imgs)))
        for i, r in enumerate(reqs):
            r.logits = logits[i]
            r.label = int(np.argmax(logits[i]))
            r.done = True
        self.served += len(reqs)
        return True

    def run(self, max_ticks: int = 10_000) -> int:
        ticks = 0
        while self.queue and ticks < max_ticks:
            self.tick()
            ticks += 1
        return ticks
