"""Batched serving engines: continuous batching over a fixed slot set.

``Engine`` serves the LM workload: requests (prompts) are admitted into free
slots; one jitted ``decode_step`` advances every active slot per tick (one
token each).  Finished slots are recycled immediately — the dataflow analogue
of the paper's stall-free pipeline: no slot waits for the longest request in
a "batch".  Prefill is per-request (token-by-token through the cache for
simplicity at test scale; the prefill_32k cell exercises the real batched
prefill path).

``ResNetEngine`` serves the paper's own workload — integer ResNet8/20 image
classification — entirely through ``repro.compile.CompiledModel``: the
optimized graph is lowered once per (backend, batch bucket) into fixed-shape
AOT executables, with the fused Pallas pipeline as the default backend, so
serving traffic takes the minimum-HBM-traffic path with zero per-tick
retracing.
"""
from __future__ import annotations

import asyncio
import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.obs import runtime as _obs
from repro.serve import sched as S

# backends whose logits must agree BITWISE with each other: a nonzero A/B
# deviation between two of these is an arithmetic bug, not quantization
# error, and trips the health monitor's bit-exactness sentinel.  The float
# shadow legitimately deviates and never counts as a mismatch.
_INT_BACKENDS = frozenset({"pallas", "pallas-stream", "lax-int", "int"})


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, cfg, params, slots: int = 4, max_len: int = 256,
                 eos: Optional[int] = None):
        self.cfg, self.params = cfg, params
        self.slots, self.max_len, self.eos = slots, max_len, eos
        self.cache = M.init_cache(cfg, slots, max_len)
        self.pos = np.zeros((slots,), np.int32)
        self.active: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []
        self.last_tok = np.zeros((slots, 1), np.int32)

        self._step = jax.jit(
            lambda p, t, pos, c: M.decode_step(p, cfg, t, pos, c))

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i in range(self.slots):
            if self.active[i] is None and self.queue:
                req = self.queue.pop(0)
                self.active[i] = req
                if not req.prompt:
                    # empty prompt: nothing to prefill (and no logits to seed
                    # from) — start decoding from token 0 at position 0 on
                    # the next tick
                    self.pos[i] = 0
                    self.last_tok[i, 0] = 0
                    continue
                # prefill token-by-token into this slot's cache
                for j, tok in enumerate(req.prompt):
                    t = self.last_tok.copy()
                    t[i, 0] = tok
                    pos = self.pos.copy()
                    pos[i] = j
                    logits, self.cache = self._step(
                        self.params, jnp.asarray(t), jnp.asarray(pos),
                        self.cache)
                self.pos[i] = len(req.prompt)
                self.last_tok[i, 0] = int(np.argmax(
                    np.asarray(logits)[i, 0]))
                req.out.append(int(self.last_tok[i, 0]))

    def tick(self):
        """One decode step for all active slots."""
        self._admit()
        if not any(r is not None for r in self.active):
            return False
        logits, self.cache = self._step(
            self.params, jnp.asarray(self.last_tok), jnp.asarray(self.pos),
            self.cache)
        nxt = np.argmax(np.asarray(logits)[:, 0, :], axis=-1)
        for i, req in enumerate(self.active):
            if req is None:
                continue
            self.pos[i] += 1
            tok = int(nxt[i])
            req.out.append(tok)
            self.last_tok[i, 0] = tok
            if len(req.out) >= req.max_new or tok == self.eos or \
                    self.pos[i] >= self.max_len - 1:
                req.done = True
                self.active[i] = None
                self.pos[i] = 0
                self.last_tok[i, 0] = 0
        return True

    def run(self, max_ticks: int = 10_000):
        done = []
        ticks = 0
        while (self.queue or any(r is not None for r in self.active)) and \
                ticks < max_ticks:
            self.tick()
            ticks += 1
        return ticks


# ---------------------------------------------------------------------------
# Image-classification serving over the fused Pallas integer pipeline
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ImageRequest:
    rid: int
    image: np.ndarray                     # (H, W, 3) float image, or an LM
                                          # (seq_len,) int token vector
    logits: Optional[np.ndarray] = None   # (num_classes | vocab,) once served
    label: Optional[int] = None
    done: bool = False


def _input_contract(cfg):
    """Per-request payload (shape, numpy dtype) of one config — the serving
    mirror of ``CompiledModel.input_spec`` minus the batch dim: float images
    for conv configs, int32 token vectors for LM configs."""
    if hasattr(cfg, "seq_len"):
        return (cfg.seq_len,), np.int32
    return (cfg.img, cfg.img, 3), np.float32


def _validate_image(cfg, req: ImageRequest) -> None:
    """Every compiled executable is fixed-shape, so a mismatched payload can
    never be batched; rejecting at submit keeps the tick loops total.
    Shared by both engines so the input contract has one home."""
    expect, _ = _input_contract(cfg)
    shape = tuple(np.shape(req.image))
    if shape != expect:
        raise ValueError(
            f"request {req.rid}: payload shape {shape} does not match the "
            f"compiled input shape {expect} for {cfg.name}")


class ResNetEngine:
    """Image-classification engine serving entirely through
    :class:`repro.compile.CompiledModel`.

    ``compile_model`` lowers the optimized graph once per (backend, batch
    bucket) into fixed-shape AOT executables; the engine then only *selects a
    bucket, zero-pads, and runs* — no retracing ever happens on a tick (the
    model's ``trace_counts`` stay at 1 per bucket, asserted in
    tests/test_serve.py).  Backends come from the ``repro.compile`` registry:

      * ``pallas`` (default) — the fused integer kernel pipeline (stem kernel
        + one add-fold kernel per residual block).
      * ``lax-int`` (alias ``int``) — the lax reference integer graph,
        bit-identical logits, unfused dataflow.
      * ``float`` — float emulation of the integer graph on the same pow2
        grids, for A/B'ing quantization error in production.

    ``ab_backends`` compiles shadow models on additional backends; every tick
    the primary batch is replayed through each shadow and the max absolute
    logit deviation is recorded in ``ab_stats`` — a live parity probe for
    canarying a new backend against the serving one.

    ``tune`` engages the ``repro.tune`` design-space layer (a per-task
    config dict / TuneResult, or ``"auto"``/``"analytic"``/``"device"``):
    the primary model serves with the tuned kernel tiling, while the
    shadows stay untuned so the A/B probe also guards the tuner.
    """

    def __init__(self, cfg, qparams, batch: int = 8, backend: str = "pallas",
                 params=None, batch_sizes=None, ab_backends=(), tune=None):
        from repro.compile import compile_model

        del params  # legacy arg; the float backend is now self-contained
        self.cfg, self.batch = cfg, batch
        self.backend = backend
        if batch_sizes is None:
            batch_sizes = (batch,)
        if batch not in batch_sizes:
            raise ValueError(
                f"max batch {batch} must be one of batch_sizes {batch_sizes}")
        # ``tune`` flows straight into compile_model: a per-task dict /
        # TuneResult from repro.tune, or "auto"/"analytic"/"device".  Tuning
        # only reschedules the kernels — logits are bit-identical — so the
        # shadows stay untuned: the A/B probe then also guards the tuner.
        self.model = compile_model(cfg, qparams, backend=backend,
                                   batch_sizes=batch_sizes, tune=tune)
        self.tuning = self.model.tuning
        self.qparams = self.model.params
        self.shadows = {name: compile_model(cfg, qparams, backend=name,
                                            batch_sizes=batch_sizes)
                        for name in ab_backends}
        self.ab_stats = {name: [] for name in self.shadows}
        self.queue: List[ImageRequest] = []
        self.served = 0

    def submit(self, req: ImageRequest):
        """Enqueue one request (shape-validated at admission)."""
        _validate_image(self.cfg, req)
        self.queue.append(req)

    def tick(self) -> bool:
        """Serve one batch; returns False when the queue is empty."""
        if not self.queue:
            return False
        reqs = self.queue[:self.batch]
        del self.queue[:len(reqs)]
        dtype = _input_contract(self.cfg)[1]
        imgs = np.stack([np.asarray(r.image, dtype) for r in reqs])
        logits = np.asarray(self.model(imgs))
        for name, shadow in self.shadows.items():
            dev = np.max(np.abs(np.asarray(shadow(imgs)) - logits))
            self.ab_stats[name].append(float(dev))
            ob = _obs.active()
            if ob is not None:
                ob.metrics.counter(
                    "ab_checks_total", "A/B shadow replays").inc(shadow=name)
                ob.metrics.gauge(
                    "ab_max_abs_dev",
                    "last max |shadow - primary| logit deviation").set(
                        float(dev), shadow=name)
                if dev > 0 and self.backend in _INT_BACKENDS \
                        and name in _INT_BACKENDS:
                    ob.metrics.counter(
                        "ab_mismatch_total",
                        "integer shadow disagreed bitwise with primary").inc(
                            shadow=name)
        for i, r in enumerate(reqs):
            r.logits = logits[i]
            r.label = int(np.argmax(logits[i]))
            r.done = True
        self.served += len(reqs)
        return True

    def run(self, max_ticks: int = 10_000) -> int:
        ticks = 0
        while self.queue and ticks < max_ticks:
            self.tick()
            ticks += 1
        return ticks


# ---------------------------------------------------------------------------
# Scale-out serving: replica pool + deadline-based batch coalescing
# ---------------------------------------------------------------------------


class ShardedResNetEngine:
    """Multi-replica image serving: the ``CompiledModel`` lowered once and
    instantiated per-device (``repro.serve.sched.ReplicaPool``), fed by a
    deadline-based batch coalescer (``repro.serve.sched.Scheduler``).

    Request lifecycle (docs/serving.md has the full diagram):

        submit (arrival stamped, optional deadline/priority)
          -> coalesce (micro-batch held open until a bucket fills or the
             oldest request's slack is exhausted: ``slack_ms`` best-effort
             window, or ``deadline - service_estimate`` with a deadline)
          -> dispatch (least-loaded replica; jax async dispatch, so multiple
             replicas genuinely overlap on multi-device hosts)
          -> harvest (block on results, stamp completion, record queue-wait
             vs compute latency split)

    Bit-exact with the single-device :class:`ResNetEngine` path: replication
    and coalescing change *where and when* a batch runs, never the
    arithmetic (asserted in tests/test_serve_sharded.py).

    ``clock`` is injectable (``sched.FakeClock``) so scheduling behavior is
    simulable; ``max_pending`` bounds admission (``submit`` raises
    ``sched.Backpressure`` when full; ``submit_async`` awaits instead).
    """

    def __init__(self, cfg, qparams, batch: int = 8, backend: str = "pallas",
                 replicas: Optional[int] = None, devices=None,
                 batch_sizes=None, slack_ms: float = 5.0, clock=None,
                 max_pending: Optional[int] = None, tune=None,
                 service_estimate_ms: float = 0.0):
        from repro.compile import compile_model

        self.cfg, self.batch = cfg, batch
        self.backend = backend
        if batch_sizes is None:
            batch_sizes = (batch,)
        if batch not in batch_sizes:
            raise ValueError(
                f"max batch {batch} must be one of batch_sizes {batch_sizes}")
        # lowered ONCE; the pool only adds per-device XLA compiles
        self.model = compile_model(cfg, qparams, backend=backend,
                                   batch_sizes=batch_sizes, tune=tune)
        self.tuning = self.model.tuning
        self.pool = S.ReplicaPool(self.model, devices=devices,
                                  replicas=replicas)
        self.sched = S.Scheduler(
            self.pool.replicas, max_batch=batch, slack_s=slack_ms * 1e-3,
            clock=clock, max_pending=max_pending,
            service_estimate_s=service_estimate_ms * 1e-3)
        self.clock = self.sched.clock
        self.served = 0
        self._in_flight: List[tuple] = []       # (Dispatch, logits array)

    # -- admission ----------------------------------------------------------

    def submit(self, req: ImageRequest, deadline_ms: Optional[float] = None,
               priority: int = 0) -> S.ScheduledRequest:
        """Admit one request.  ``deadline_ms`` is relative to now; omit it
        for best-effort coalescing under the ``slack_ms`` window.  Raises
        ``sched.Backpressure`` at ``max_pending``."""
        _validate_image(self.cfg, req)
        deadline_in = None if deadline_ms is None else deadline_ms * 1e-3
        return self.sched.submit(req, deadline_in=deadline_in,
                                 priority=priority)

    async def submit_async(self, req: ImageRequest,
                           deadline_ms: Optional[float] = None,
                           priority: int = 0,
                           retry_s: float = 1e-3) -> S.ScheduledRequest:
        """``submit`` with backpressure-as-await: when the pending queue is
        full, yield to the event loop (letting ``run_async`` drain) and
        retry instead of raising."""
        while True:
            try:
                return self.submit(req, deadline_ms=deadline_ms,
                                   priority=priority)
            except S.Backpressure:
                await asyncio.sleep(retry_s)

    # -- dispatch loop ------------------------------------------------------

    @property
    def outstanding(self) -> int:
        return self.sched.outstanding

    def tick(self) -> bool:
        """One scheduler round: dispatch every due micro-batch (async — the
        arrays are not blocked on, so replicas overlap), then harvest all
        in-flight results.  Returns True if any work was done."""
        dispatched = self._dispatch_due()
        harvested = self._harvest()
        return bool(dispatched or harvested)

    def _dispatch_due(self) -> int:
        n = 0
        while True:
            d = self.sched.poll()
            if d is None:
                break
            dtype = _input_contract(self.cfg)[1]
            imgs = np.stack([np.asarray(r.payload.image, dtype)
                             for r in d.requests])
            out = self.pool.run(d.replica.index, imgs)   # async dispatch
            self._in_flight.append((d, out))
            n += 1
        return n

    def _next_ready_index(self) -> Optional[int]:
        """Index of a dispatch whose result is already materialized, else
        None.  Harvesting ready-first matters twice: blocking strictly FIFO
        would stamp a fast replica's completion with a slow replica's wait
        (inflating compute_ms and the deadline EWMA), and would hold the
        loop hostage to the slowest replica while due batches could be
        dispatching to idle ones."""
        for i, (_, out) in enumerate(self._in_flight):
            is_ready = getattr(out, "is_ready", None)
            if is_ready is not None and is_ready():
                return i
        return None

    def _harvest(self, block: bool = True) -> int:
        """Complete every dispatch whose result is ready; when ``block`` and
        nothing at all was ready, wait on the oldest so the caller always
        makes progress.  Returns between harvests as soon as the remainder
        is still computing — the drive loops interleave ``_dispatch_due``
        so idle replicas never wait head-of-line on a slow one."""
        n = 0
        while self._in_flight:
            i = self._next_ready_index()
            if i is None:
                if not block or n > 0:
                    break         # let the caller dispatch more work first
                i = 0             # nothing ready anywhere: wait on the oldest
            d, out = self._in_flight[i]
            try:
                logits = np.asarray(jax.block_until_ready(out))
            except Exception:
                # a dispatch whose device execution errored must not jam the
                # head of the line or leak in-flight slots: evict it, release
                # the scheduler accounting (its requests stay done=False so
                # callers can see the failure), then surface the error
                self._in_flight.pop(i)
                self.sched.complete(d, failed=True)
                raise
            self._in_flight.pop(i)
            self.sched.complete(d)
            for j, r in enumerate(d.requests):
                r.payload.logits = logits[j]
                r.payload.label = int(np.argmax(logits[j]))
                r.payload.done = True
            self.served += len(d)
            n += 1
        return n

    def run(self, max_ticks: int = 100_000) -> int:
        """Drive until everything admitted so far is served.  When nothing
        is due yet (the coalescer is holding a batch open), sleeps the clock
        to the next dispatch-by time instead of spinning."""
        ticks = 0
        while self.outstanding and ticks < max_ticks:
            if not self.tick():
                self._sleep_until_due()
            ticks += 1
        return ticks

    def _sleep_until_due(self) -> None:
        due_at = self.sched.next_due_at()
        if due_at is None:
            return
        self.clock.sleep(max(due_at - self.clock.now(), 1e-4))

    async def run_async(self, idle_sleep_s: float = 1e-3) -> int:
        """Async dispatch loop: serve until the engine is shut down *and*
        drained.  Producers ``submit``/``submit_async`` concurrently; call
        ``shutdown()`` to let the loop finish the tail and return.  The
        blocking wait on device results runs off the event loop, so
        producers keep filling the next micro-batch during compute."""
        ticks = 0
        while not (self.sched.closed and not self.outstanding
                   and not self._in_flight):
            progressed = self._dispatch_due() > 0
            if self._in_flight:
                progressed |= bool(
                    await asyncio.to_thread(self._harvest))
            if progressed:
                await asyncio.sleep(0)           # yield to producers
            else:
                await asyncio.sleep(idle_sleep_s)
            ticks += 1
        return ticks

    def shutdown(self) -> None:
        """Stop admission; pending requests become due immediately and drain
        through the normal dispatch path (graceful drain)."""
        self.sched.shutdown()

    # -- autoscaling hooks --------------------------------------------------

    @property
    def active_replicas(self) -> int:
        """Replicas currently receiving new dispatches (autoscaler-set)."""
        return self.sched.active

    def set_active_replicas(self, n: int, reason: str = None) -> int:
        """Actuate an autoscaling decision: route new dispatches to the
        first ``n`` replicas only (clamped to the pool size).  Deactivated
        replicas finish their in-flight work and keep their executables
        warm, so scaling back up is instant.  ``reason`` (the policy
        trigger) is recorded on the scheduler's scale-event accounting."""
        return self.sched.set_active(n, reason=reason)

    @property
    def queue_depth(self) -> int:
        """Requests admitted but not yet dispatched — the autoscaler's
        primary pressure signal."""
        return self.sched.pending

    # -- introspection ------------------------------------------------------

    def latency_stats(self) -> dict:
        """p50/p99 queue-wait vs compute split, deadline accounting, and
        per-replica served/dispatched counts."""
        return self.sched.summary()

    def stats(self) -> dict:
        # latency_stats() already carries the per-replica breakdown under
        # 'replicas'; only add what it doesn't have
        return dict(model=self.model.stats(), pool_size=len(self.pool),
                    served=self.served, **self.latency_stats())
