"""Deadline-based batch coalescing + replica scheduling for ResNet serving.

The paper's throughput numbers (Table 3: 12971/3254 FPS ResNet8/20 on the
Ultra96) come from keeping every compute unit saturated under streaming
traffic.  The software analogue splits into two orthogonal mechanisms, both
here:

* **Batch coalescing** (:class:`BatchCoalescer`): a micro-batch is held open
  until either a bucket fills or the *oldest* request's deadline slack is
  exhausted — the classic latency/throughput dial.  Requests carry an
  ``arrival`` timestamp and an optional absolute ``deadline``; the coalescer
  dispatches a batch no later than ``deadline - service_estimate`` so the
  compute itself still fits before the deadline (when capacity suffices).

* **Replica scheduling** (:class:`Scheduler` + :class:`ReplicaPool`): the
  compiled model is instantiated once per device (the analogue of the
  paper's replicated accelerator pipelines); each dispatch goes to the
  least-loaded replica, with per-replica in-flight accounting.  Results are
  bit-exact with the single-device path — replication never changes the
  arithmetic, only where it runs.

Everything in this module is driven by an injectable :class:`Clock`, so the
scheduling policy is testable under a :class:`FakeClock` simulation with no
real model, no real time, and no flakiness (tests/test_sched.py).  The
engine (`serve.engine.ShardedResNetEngine`) wires a real clock, a real
:class:`ReplicaPool`, and the async dispatch loop around this core.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, List, Optional, Sequence

import numpy as np

from repro.obs import runtime as _obs


# ---------------------------------------------------------------------------
# Clocks — injectable time source so scheduling is simulable
# ---------------------------------------------------------------------------


class MonotonicClock:
    """Wall clock: ``time.monotonic`` + real ``time.sleep``."""

    def now(self) -> float:
        return time.monotonic()

    def sleep(self, dt: float) -> None:
        if dt > 0:
            time.sleep(dt)


class FakeClock:
    """Deterministic simulation clock: ``sleep`` advances time instantly."""

    def __init__(self, t0: float = 0.0):
        self._t = float(t0)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> None:
        assert dt >= 0
        self._t += dt

    def sleep(self, dt: float) -> None:
        self.advance(max(dt, 0.0))


# ---------------------------------------------------------------------------
# Requests and dispatches
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ScheduledRequest:
    """One admitted request moving through arrive → coalesce → dispatch →
    complete.  ``payload`` is opaque to the scheduler (the engine stores its
    ``ImageRequest`` there)."""

    payload: Any
    seq: int                          # admission order (FIFO tiebreak)
    arrival: float                    # clock.now() at submit
    deadline: Optional[float] = None  # absolute; None = best-effort
    priority: int = 0                 # lower value = more urgent class
    dispatch_t: Optional[float] = None
    complete_t: Optional[float] = None
    replica: Optional[int] = None

    @property
    def queue_wait(self) -> Optional[float]:
        if self.dispatch_t is None:
            return None
        return self.dispatch_t - self.arrival

    @property
    def compute_time(self) -> Optional[float]:
        if self.complete_t is None or self.dispatch_t is None:
            return None
        return self.complete_t - self.dispatch_t

    @property
    def deadline_met(self) -> Optional[bool]:
        if self.deadline is None:
            return None
        if self.complete_t is None:
            return False
        return self.complete_t <= self.deadline


@dataclasses.dataclass
class Dispatch:
    """One micro-batch bound to one replica."""

    requests: List[ScheduledRequest]
    replica: "ReplicaState"
    dispatch_t: float

    def __len__(self) -> int:
        return len(self.requests)


class DrainResult(int):
    """``Scheduler.drain``'s return value: still the flushed-dispatch count
    (an ``int``, so every existing ``n == k`` consumer is untouched), plus
    ``missed_deadline`` — how many of the drained *requests* had already
    blown their deadline by the time the drain dispatched them.  Overload
    experiments use the split to distinguish "served late" from "served in
    time" in the tail that shutdown flushes."""

    def __new__(cls, dispatches: int, missed_deadline: int = 0):
        obj = super().__new__(cls, dispatches)
        obj.missed_deadline = int(missed_deadline)
        return obj


class Backpressure(RuntimeError):
    """Raised by ``submit`` when the pending queue is at ``max_pending`` —
    the caller must retry later (``submit_async`` awaits instead)."""


class SchedulerClosed(RuntimeError):
    """Raised by ``submit`` after ``shutdown()``: draining, not admitting."""


# ---------------------------------------------------------------------------
# Batch coalescer
# ---------------------------------------------------------------------------


class BatchCoalescer:
    """Hold a micro-batch open until a bucket fills or slack runs out.

    A request must be *dispatched* by

        ``deadline - service_estimate``    (it has a deadline), or
        ``arrival + slack``                (best-effort coalescing window)

    ``due(now)`` is True as soon as the batch is full or any pending request
    has reached its dispatch-by time; ``take()`` then pops up to
    ``max_batch`` requests, FIFO within each priority class (lower priority
    value first — stable, so same-class requests keep admission order).
    """

    def __init__(self, max_batch: int, slack_s: float = 0.005):
        if max_batch <= 0:
            raise ValueError(f"max_batch must be positive: {max_batch}")
        self.max_batch = int(max_batch)
        self.slack_s = float(slack_s)
        self.pending: List[ScheduledRequest] = []

    def __len__(self) -> int:
        return len(self.pending)

    def add(self, sreq: ScheduledRequest) -> None:
        self.pending.append(sreq)

    def dispatch_by(self, sreq: ScheduledRequest,
                    service_estimate_s: float = 0.0) -> float:
        if sreq.deadline is not None:
            if service_estimate_s <= 0.0:
                # cold start: with no service-time observation yet, a
                # deadline cannot be budgeted against — dispatch at once
                # rather than holding until the deadline and guaranteeing
                # a miss (the first completion seeds the EWMA)
                return sreq.arrival
            return sreq.deadline - service_estimate_s
        return sreq.arrival + self.slack_s

    def due(self, now: float, service_estimate_s: float = 0.0) -> bool:
        if len(self.pending) >= self.max_batch:
            return True
        return any(self.dispatch_by(r, service_estimate_s) <= now
                   for r in self.pending)

    def next_due_at(self, service_estimate_s: float = 0.0) -> Optional[float]:
        """Earliest dispatch-by time over pending requests (None if empty) —
        how long a driver may sleep before anything can become due."""
        if not self.pending:
            return None
        return min(self.dispatch_by(r, service_estimate_s)
                   for r in self.pending)

    def take(self) -> List[ScheduledRequest]:
        """Pop up to ``max_batch`` requests: most urgent priority class
        first, FIFO (admission order) inside each class."""
        batch = sorted(self.pending,
                       key=lambda r: (r.priority, r.seq))[:self.max_batch]
        taken = {id(r) for r in batch}
        self.pending = [r for r in self.pending if id(r) not in taken]
        return batch


# ---------------------------------------------------------------------------
# Replica state + least-loaded selection
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ReplicaState:
    """Bookkeeping for one model replica (one device)."""

    index: int
    device: Any = None                # jax Device for real pools; None in sims
    in_flight: int = 0                # requests dispatched, not yet complete
    dispatched: int = 0               # lifetime request count
    served: int = 0                   # lifetime completed count
    failed: int = 0                   # lifetime failed-dispatch count

    @property
    def load(self) -> int:
        return self.in_flight


def least_loaded(replicas: Sequence[ReplicaState]) -> ReplicaState:
    """Fewest in-flight requests; ties broken by fewest lifetime dispatches,
    then lowest index (deterministic)."""
    return min(replicas, key=lambda r: (r.in_flight, r.dispatched, r.index))


# ---------------------------------------------------------------------------
# Latency accounting
# ---------------------------------------------------------------------------


class LatencyStats:
    """Per-request queue-wait and compute samples with percentile summary.

    Samples are also bucketed by the request's ``priority`` class so SLO
    layers (``repro.traffic.slo``) can report per-class percentiles and
    deadline accounting; the flat top-level summary keys are unchanged —
    existing consumers never see a different shape, only the additional
    ``by_priority`` breakdown."""

    def __init__(self):
        self.queue_wait_s: List[float] = []
        self.compute_s: List[float] = []
        self.deadline_misses = 0
        self.deadline_total = 0
        self.failed = 0                   # requests whose dispatch errored
        self._by_priority: dict = {}      # priority -> per-class sample store

    def _class(self, priority: int) -> dict:
        return self._by_priority.setdefault(
            priority, dict(queue_wait_s=[], compute_s=[],
                           deadline_misses=0, deadline_total=0))

    def record(self, sreq: ScheduledRequest) -> None:
        self.queue_wait_s.append(sreq.queue_wait)
        self.compute_s.append(sreq.compute_time)
        cls = self._class(sreq.priority)
        cls["queue_wait_s"].append(sreq.queue_wait)
        cls["compute_s"].append(sreq.compute_time)
        if sreq.deadline is not None:
            self.deadline_total += 1
            cls["deadline_total"] += 1
            if not sreq.deadline_met:
                self.deadline_misses += 1
                cls["deadline_misses"] += 1

    @staticmethod
    def _pct(xs: List[float]) -> dict:
        if not xs:
            return dict(p50=0.0, p99=0.0, max=0.0)
        a = np.asarray(xs, np.float64) * 1e3          # -> milliseconds
        return dict(p50=float(np.percentile(a, 50)),
                    p99=float(np.percentile(a, 99)),
                    max=float(a.max()))

    def priority_summary(self) -> dict:
        """Per-priority-class breakdown: same keys as the flat summary,
        keyed by the priority value (lower = more urgent)."""
        return {p: dict(count=len(c["queue_wait_s"]),
                        queue_wait_ms=self._pct(c["queue_wait_s"]),
                        compute_ms=self._pct(c["compute_s"]),
                        deadline_misses=c["deadline_misses"],
                        deadline_total=c["deadline_total"])
                for p, c in sorted(self._by_priority.items())}

    def summary(self) -> dict:
        return dict(count=len(self.queue_wait_s),
                    queue_wait_ms=self._pct(self.queue_wait_s),
                    compute_ms=self._pct(self.compute_s),
                    deadline_misses=self.deadline_misses,
                    deadline_total=self.deadline_total,
                    failed=self.failed,
                    by_priority=self.priority_summary())


# ---------------------------------------------------------------------------
# The scheduler: coalescer + replicas + clock
# ---------------------------------------------------------------------------


class Scheduler:
    """Deadline-aware dispatch over a set of replicas.

    Execution-agnostic: ``poll`` hands out a :class:`Dispatch` (requests +
    chosen replica) and the caller runs it however it likes — the engine on
    real compiled executables, the tests against a fake service time — then
    reports back via ``complete``.  The service-time estimate used for
    deadline headroom is an EWMA over observed per-batch compute times,
    seeded by ``service_estimate_s``.
    """

    def __init__(self, replicas, max_batch: int, slack_s: float = 0.005,
                 clock=None, max_pending: Optional[int] = None,
                 service_estimate_s: float = 0.0, ewma: float = 0.25):
        if isinstance(replicas, int):
            replicas = [ReplicaState(i) for i in range(replicas)]
        if not replicas:
            raise ValueError("need at least one replica")
        self.replicas: List[ReplicaState] = list(replicas)
        self.coalescer = BatchCoalescer(max_batch, slack_s=slack_s)
        self.clock = clock if clock is not None else MonotonicClock()
        self.max_pending = max_pending
        self.service_estimate_s = float(service_estimate_s)
        self.ewma = float(ewma)
        self.closed = False
        self.stats = LatencyStats()
        self._seq = 0
        self._in_flight_reqs = 0
        # dispatches go to the least-loaded replica among the first
        # ``active`` — the autoscaling hook (repro.traffic.autoscale):
        # shrinking never cancels in-flight work on a deactivated replica,
        # it only stops routing new batches there
        self.active = len(self.replicas)
        self.drained_missed_deadline = 0
        # autoscaler actuation record, surfaced by summary() so scale
        # activity is reachable from every CLI/benchmark JSON
        self.scale_events = 0
        self.last_scale_reason: Optional[str] = None

    # -- admission ----------------------------------------------------------

    def submit(self, payload, deadline: Optional[float] = None,
               deadline_in: Optional[float] = None,
               priority: int = 0) -> ScheduledRequest:
        """Admit one request.  ``deadline`` is absolute (clock domain);
        ``deadline_in`` is relative to now.  Raises :class:`Backpressure`
        when the pending queue is full and :class:`SchedulerClosed` after
        ``shutdown()``."""
        if self.closed:
            raise SchedulerClosed("scheduler is shut down; draining only")
        if self.max_pending is not None and \
                len(self.coalescer) >= self.max_pending:
            ob = _obs.active()
            if ob is not None:
                ob.metrics.counter(
                    "sched_backpressure_total",
                    "submits rejected at max_pending").inc()
            raise Backpressure(
                f"pending queue at max_pending={self.max_pending}")
        now = self.clock.now()
        if deadline_in is not None:
            if deadline is not None:
                raise ValueError("pass deadline or deadline_in, not both")
            deadline = now + deadline_in
        sreq = ScheduledRequest(payload=payload, seq=self._seq, arrival=now,
                                deadline=deadline, priority=priority)
        self._seq += 1
        self.coalescer.add(sreq)
        ob = _obs.active()
        if ob is not None:
            ob.metrics.counter(
                "sched_submitted_total", "requests admitted").inc(
                    priority=str(priority))
        return sreq

    # -- dispatch -----------------------------------------------------------

    @property
    def pending(self) -> int:
        return len(self.coalescer)

    @property
    def in_flight(self) -> int:
        return self._in_flight_reqs

    @property
    def outstanding(self) -> int:
        """Requests admitted but not yet completed."""
        return self.pending + self._in_flight_reqs

    def poll(self, now: Optional[float] = None) -> Optional[Dispatch]:
        """Return the next due micro-batch bound to the least-loaded replica,
        or None when nothing is due yet.  After ``shutdown()`` every pending
        request is due immediately (graceful drain)."""
        if not self.coalescer.pending:
            return None
        if now is None:
            now = self.clock.now()
        if not self.closed and \
                not self.coalescer.due(now, self.service_estimate_s):
            return None
        batch = self.coalescer.take()
        rep = least_loaded(self.replicas[:self.active])
        for r in batch:
            r.dispatch_t = now
            r.replica = rep.index
        rep.in_flight += len(batch)
        rep.dispatched += len(batch)
        self._in_flight_reqs += len(batch)
        ob = _obs.active()
        if ob is not None:
            first = min(r.arrival for r in batch)
            ob.trace.span("coalesce_hold", cat="sched", track="coalesce",
                          t0=first, t1=now, batch=len(batch),
                          replica=rep.index)
            ob.metrics.counter(
                "sched_dispatches_total", "micro-batches dispatched").inc(
                    replica=str(rep.index))
            ob.metrics.histogram(
                "sched_coalesce_hold_ms",
                "oldest-request hold time per dispatched batch").observe(
                    (now - first) * 1e3)
        return Dispatch(requests=batch, replica=rep, dispatch_t=now)

    def next_due_at(self) -> Optional[float]:
        return self.coalescer.next_due_at(self.service_estimate_s)

    def set_active(self, n: int, reason: Optional[str] = None) -> int:
        """Restrict dispatch to the first ``n`` replicas (clamped to
        ``[1, len(replicas)]``); returns the applied value.  The autoscaler's
        actuation point — replicas beyond ``active`` keep their executables
        warm and finish what they hold, they just stop receiving work.

        An actual change counts as a scale event (``scale_events`` /
        ``last_scale_reason``, surfaced by :meth:`summary`); ``reason`` is
        the caller's policy trigger (the autoscaler passes its
        ``ScaleDecision.reason``)."""
        applied = max(1, min(int(n), len(self.replicas)))
        if applied != self.active:
            prev, self.active = self.active, applied
            self.scale_events += 1
            if reason is not None:
                self.last_scale_reason = reason
            ob = _obs.active()
            if ob is not None:
                ob.trace.instant("scale", cat="control", track="control",
                                 from_replicas=prev, to_replicas=applied,
                                 reason=reason)
                ob.metrics.counter(
                    "sched_scale_events_total",
                    "applied active-replica changes").inc(
                        reason=str(reason))
                ob.metrics.gauge(
                    "sched_active_replicas",
                    "replicas currently receiving work").set(applied)
        return self.active

    def complete(self, dispatch: Dispatch, now: Optional[float] = None,
                 failed: bool = False) -> None:
        """Report a dispatch finished: releases the replica's in-flight
        slots and, on success, stamps completion times, records latency and
        updates the service-time EWMA.  ``failed=True`` (the dispatch
        errored) only releases the accounting — failed requests must never
        appear as served, met deadlines, or service-time observations."""
        if now is None:
            now = self.clock.now()
        rep = dispatch.replica
        rep.in_flight -= len(dispatch)
        self._in_flight_reqs -= len(dispatch)
        ob = _obs.active()
        if failed:
            rep.failed += len(dispatch)
            self.stats.failed += len(dispatch)
            if ob is not None:
                ob.metrics.counter(
                    "sched_failed_total",
                    "requests whose dispatch errored").inc(
                        len(dispatch), replica=str(rep.index))
            return
        for r in dispatch.requests:
            r.complete_t = now
            self.stats.record(r)
        rep.served += len(dispatch)
        if ob is not None:
            ob.trace.span("batch", cat="sched", track=f"replica{rep.index}",
                          t0=dispatch.dispatch_t, t1=now, n=len(dispatch))
            for r in dispatch.requests:
                ob.trace.span("queue_wait", cat="sched", track="requests",
                              t0=r.arrival, t1=r.dispatch_t, seq=r.seq,
                              priority=r.priority, replica=rep.index)
                ob.trace.span("compute", cat="sched", track="requests",
                              t0=r.dispatch_t, t1=now, seq=r.seq,
                              priority=r.priority, replica=rep.index,
                              **({} if r.deadline is None
                                 else dict(deadline_met=bool(r.deadline_met))))
                ob.metrics.histogram(
                    "sched_queue_wait_ms",
                    "admit-to-dispatch wait per request").observe(
                        r.queue_wait * 1e3, priority=str(r.priority))
                ob.metrics.histogram(
                    "sched_compute_ms",
                    "dispatch-to-complete time per request").observe(
                        r.compute_time * 1e3, priority=str(r.priority))
                if r.deadline is not None:
                    ob.metrics.counter(
                        "sched_deadline_total",
                        "deadline-carrying completions by outcome").inc(
                            outcome="met" if r.deadline_met else "missed",
                            priority=str(r.priority))
            ob.metrics.counter(
                "sched_served_total", "requests completed").inc(
                    len(dispatch), replica=str(rep.index))
        observed = now - dispatch.dispatch_t
        if self.service_estimate_s <= 0.0:
            self.service_estimate_s = observed
        else:
            self.service_estimate_s += self.ewma * \
                (observed - self.service_estimate_s)

    # -- shutdown -----------------------------------------------------------

    def shutdown(self) -> None:
        """Stop admitting; everything already pending becomes due and drains
        through the normal poll/complete cycle."""
        self.closed = True

    def drain(self, execute: Callable[[Dispatch], None]) -> DrainResult:
        """Graceful shutdown helper: close admission, then run every
        remaining dispatch through ``execute`` (which must call
        ``complete``).  Returns a :class:`DrainResult` — the number of
        dispatches flushed (an ``int``, back-compatible) carrying
        ``missed_deadline``: how many drained requests had already missed
        their deadline at dispatch time (served late vs served in time)."""
        self.shutdown()
        n = 0
        missed = 0
        while True:
            d = self.poll()
            if d is None:
                break
            missed += sum(1 for r in d.requests
                          if r.deadline is not None
                          and r.deadline < d.dispatch_t)
            execute(d)
            n += 1
        self.drained_missed_deadline += missed
        ob = _obs.active()
        if ob is not None:
            ob.trace.instant("drain", cat="sched", track="control",
                             dispatches=n, missed_deadline=missed)
            ob.metrics.counter(
                "sched_drained_dispatches_total",
                "dispatches flushed by drain()").inc(n)
            if missed and ob.health is not None:
                # drain finished late: freeze a post-mortem debug bundle
                ob.health.on_drain(missed, dispatches=n)
        return DrainResult(n, missed)

    def summary(self) -> dict:
        return dict(replicas=[dict(index=r.index, served=r.served,
                                   dispatched=r.dispatched,
                                   in_flight=r.in_flight, failed=r.failed)
                              for r in self.replicas],
                    active_replicas=self.active,
                    service_estimate_ms=self.service_estimate_s * 1e3,
                    drained_missed_deadline=self.drained_missed_deadline,
                    scale_events=self.scale_events,
                    last_scale_reason=self.last_scale_reason,
                    **self.stats.summary())


# ---------------------------------------------------------------------------
# Replica pool — one compiled executable set per device
# ---------------------------------------------------------------------------


class ReplicaPool:
    """A :class:`~repro.compile.CompiledModel` instantiated once per device.

    The model is *lowered* once (graph walk + backend closure); each replica
    then gets its own per-device AOT executables via
    ``CompiledModel.device_executable`` — the software analogue of stamping
    N copies of the accelerator pipeline onto the fabric, each with its own
    weight copy in BRAM.  ``run`` pins a batch to one replica's device and
    is bit-exact with the single-device path (replication does not touch the
    arithmetic).
    """

    def __init__(self, model, devices: Optional[Sequence] = None,
                 replicas: Optional[int] = None):
        import jax

        if devices is None:
            devices = jax.local_devices()
        devices = list(devices)
        if replicas is not None:
            if len(devices) < replicas:
                raise ValueError(
                    f"asked for {replicas} replicas but only {len(devices)} "
                    f"devices are available: {devices}")
            devices = devices[:replicas]
        if not devices:
            raise ValueError("need at least one device")
        self.model = model
        self.devices = list(devices)
        self.replicas = [ReplicaState(i, device=d)
                         for i, d in enumerate(self.devices)]

    def __len__(self) -> int:
        return len(self.replicas)

    def run(self, index: int, images):
        """Run one batch on replica ``index``'s device (async dispatch: the
        returned array is not blocked on)."""
        return self.model.run_placed(images, self.devices[index])

    def warmup(self) -> "ReplicaPool":
        """Eagerly compile every (bucket, device) executable so serving never
        pays a compile on the hot path."""
        for d in self.devices:
            for b in self.model.batch_sizes:
                self.model.device_executable(b, d)
        return self
