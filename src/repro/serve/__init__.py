"""``repro.serve`` — serving engines and the scheduling layer.

* ``engine``: :class:`~repro.serve.engine.Engine` (LM continuous batching),
  :class:`~repro.serve.engine.ResNetEngine` (single-device compiled image
  serving), :class:`~repro.serve.engine.ShardedResNetEngine` (replica pool +
  deadline-based batch coalescing).
* ``sched``: the execution-agnostic scheduling core — injectable clocks,
  :class:`~repro.serve.sched.BatchCoalescer`,
  :class:`~repro.serve.sched.Scheduler`,
  :class:`~repro.serve.sched.ReplicaPool`.
"""
from repro.serve.engine import (                         # noqa: F401
    Engine, ImageRequest, Request, ResNetEngine, ShardedResNetEngine)
from repro.serve.sched import (                          # noqa: F401
    Backpressure, BatchCoalescer, Dispatch, DrainResult, FakeClock,
    LatencyStats, MonotonicClock, ReplicaPool, ReplicaState,
    ScheduledRequest, Scheduler, SchedulerClosed, least_loaded)
